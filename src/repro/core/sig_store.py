"""Array-backed signature store S (paper §3.2, sorted-file implementation).

The paper keeps S as a sorted file of (signature, pId) records; lookups and
inserts are bulk sort/merge passes. The previous in-memory analogue was a
Python dict per level — correct, but it forced every store interaction
(construction extract, maintenance resolve) through a per-node Python loop.

``SigStore`` is the array-native replacement: one sorted ``uint64`` key
column (the fused ``hi << 32 | lo`` signature hash) plus a parallel
``int64`` pid column.  The store operations are exactly the paper's bulk
ones:

  * lookup  — ``np.searchsorted`` of the (sorted) probe keys against the
              key column: the sort-merge join of F against S.
  * insert  — sort + dedup the novel run, then a single merge with the
              existing sorted run (``np.argsort`` of the concatenation is
              O((n+m) log) but allocation-light; both runs already sorted).
  * get_or_assign — the combined "resolve or create pId" step of
              Algorithm 4 lines 13-17, over a whole frontier at once.

Level 0 reuses the same store with ``key = uint64(node_label)`` (hi lane 0),
so construction and maintenance share one schema for every level.
"""
from __future__ import annotations

import numpy as np

_U64 = np.uint64
_SHIFT = np.uint64(32)


def fuse_key(hi, lo) -> np.ndarray:
    """Fuse (hi, lo) u32 hash lanes into the store's sortable u64 key."""
    hi = np.asarray(hi).astype(np.uint32, copy=False)
    lo = np.asarray(lo).astype(np.uint32, copy=False)
    return (hi.astype(_U64) << _SHIFT) | lo.astype(_U64)


def label_key(labels) -> np.ndarray:
    """Level-0 key: the raw node label in the lo lane (hi lane zero)."""
    return np.asarray(labels).astype(np.uint32, copy=False).astype(_U64)


class SigStore:
    """Sorted (key u64, pid int64) columns; all ops are bulk array ops."""

    __slots__ = ("keys", "pids")

    def __init__(self, keys: np.ndarray, pids: np.ndarray, *,
                 presorted: bool = False):
        keys = np.asarray(keys, dtype=_U64)
        pids = np.asarray(pids, dtype=np.int64)
        if keys.shape != pids.shape:
            raise ValueError("keys and pids must be parallel 1-D arrays")
        if not presorted:
            keys, first = np.unique(keys, return_index=True)
            pids = pids[first]
        self.keys = keys
        self.pids = pids

    # ------------------------------------------------------------ builders
    @classmethod
    def empty(cls) -> "SigStore":
        return cls(np.empty(0, _U64), np.empty(0, np.int64), presorted=True)

    @classmethod
    def from_hash_pairs(cls, hi, lo, pids) -> "SigStore":
        """Build from per-node (hi, lo, pid) arrays; duplicates collapse
        (all nodes with one signature share a pid by construction)."""
        return cls(fuse_key(hi, lo), pids)

    @classmethod
    def from_labels(cls, labels, pids) -> "SigStore":
        return cls(label_key(labels), pids)

    # ------------------------------------------------------------- queries
    def __len__(self) -> int:
        return int(self.keys.shape[0])

    def __contains__(self, key) -> bool:
        k = _U64(key)
        i = np.searchsorted(self.keys, k)
        return bool(i < self.keys.shape[0] and self.keys[i] == k)

    def lookup(self, keys) -> tuple[np.ndarray, np.ndarray]:
        """Bulk lookup. Returns (pids int64, found bool); missing -> -1."""
        keys = np.asarray(keys, dtype=_U64)
        idx = np.searchsorted(self.keys, keys)
        idx_c = np.minimum(idx, max(len(self) - 1, 0))
        found = (np.zeros(keys.shape, bool) if len(self) == 0
                 else self.keys[idx_c] == keys)
        out = np.where(found, self.pids[idx_c] if len(self) else -1, -1)
        return out.astype(np.int64, copy=False), found

    def get(self, key, default=None):
        pid, found = self.lookup(np.asarray([key], dtype=_U64))
        return int(pid[0]) if found[0] else default

    # ------------------------------------------------------------- updates
    def insert(self, keys, pids) -> None:
        """Merge (keys, pids) into the store. Existing keys keep their pid
        (the store is an injective signature -> pId map; re-inserting an
        existing signature with a different pid would be a logic error)."""
        keys = np.asarray(keys, dtype=_U64)
        pids = np.asarray(pids, dtype=np.int64)
        if keys.size == 0:
            return
        ukeys, first = np.unique(keys, return_index=True)
        upids = pids[first]
        _, found = self.lookup(ukeys)
        novel = ~found
        if not novel.any():
            return
        merged_keys = np.concatenate([self.keys, ukeys[novel]])
        merged_pids = np.concatenate([self.pids, upids[novel]])
        order = np.argsort(merged_keys, kind="stable")
        self.keys = merged_keys[order]
        self.pids = merged_pids[order]

    def get_or_assign(self, keys, next_pid: int) -> tuple[np.ndarray, int]:
        """Resolve every key to a pid, minting fresh pids for novel keys.

        New pids are assigned in order of first occurrence in `keys`
        (matching what a sequential dict walk over the frontier would do),
        starting at `next_pid`. Returns (pids int64 [len(keys)], next_pid').
        """
        keys = np.asarray(keys, dtype=_U64)
        out, found = self.lookup(keys)
        if found.all():
            return out, next_pid
        miss = ~found
        mkeys = keys[miss]
        ukeys, first, inv = np.unique(mkeys, return_index=True,
                                      return_inverse=True)
        # rank unique novel keys by first appearance in the probe order
        appearance = np.argsort(np.argsort(first, kind="stable"),
                                kind="stable")
        new_pids = np.int64(next_pid) + appearance
        out[miss] = new_pids[inv]
        merged_keys = np.concatenate([self.keys, ukeys])
        merged_pids = np.concatenate([self.pids, new_pids])
        order = np.argsort(merged_keys, kind="stable")
        self.keys = merged_keys[order]
        self.pids = merged_pids[order]
        return out, next_pid + int(ukeys.shape[0])

    # --------------------------------------------------------------- misc
    def to_dict(self) -> dict:
        """Materialize as {int key: int pid} (tests / debugging only)."""
        return {int(k): int(p) for k, p in zip(self.keys.tolist(),
                                               self.pids.tolist())}

    def slice_copy(self) -> "SigStore":
        return SigStore(self.keys.copy(), self.pids.copy(), presorted=True)
