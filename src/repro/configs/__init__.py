"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "llama4_scout_17b_16e",
    "deepseek_v2_lite_16b",
    "zamba2_7b",
    "mamba2_780m",
    "phi4_mini_3p8b",
    "minicpm3_4b",
    "qwen1p5_110b",
    "gemma2_9b",
    "llava_next_34b",
    "seamless_m4t_large_v2",
]

# CLI names (dashes) -> module names
ALIASES = {a.replace("_", "-").replace("p", "."): a for a in ARCH_IDS}


def get_config(arch: str):
    name = arch.replace("-", "_").replace(".", "p")
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{name}").CONFIG


def get_smoke_config(arch: str):
    name = arch.replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{name}").SMOKE_CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
