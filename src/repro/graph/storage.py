"""Graph storage: the framework analogue of the paper's N_t / E_t tables.

The paper stores the graph as two disk-resident column tables:
  N_t(nId, nLabel, pId_0, pId_old, pId_new)   and   E_t(sId, eLabel, tId, pId_old_tId)
kept in several sort orders (E_tst by (sId,tId), E_tts by (tId,sId)).

Here the analogue is a struct-of-arrays `Graph` whose edge columns are kept
canonically sorted by (src, elabel, dst) — the sort order Algorithm 1 needs —
plus CSR offsets for both directions (the analogue of the E_tst / E_tts
copies used by the maintenance algorithms).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """Directed node- and edge-labeled graph <N, E, lambda_N, lambda_E>."""

    node_labels: np.ndarray  # int32 [N]
    src: np.ndarray          # int32 [E], sorted (src, elabel, dst)
    dst: np.ndarray          # int32 [E]
    elabel: np.ndarray       # int32 [E]

    @property
    def num_nodes(self) -> int:
        return int(self.node_labels.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def __post_init__(self):
        self.node_labels = np.asarray(self.node_labels, dtype=np.int32)
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.elabel = np.asarray(self.elabel, dtype=np.int32)
        if self.src.shape != self.dst.shape or self.src.shape != self.elabel.shape:
            raise ValueError("edge columns must have identical shapes")
        if self.num_edges:
            if self.src.min() < 0 or self.src.max() >= self.num_nodes:
                raise ValueError("src out of range")
            if self.dst.min() < 0 or self.dst.max() >= self.num_nodes:
                raise ValueError("dst out of range")

    # ---------------------------------------------------------------- builds
    @staticmethod
    def from_edges(node_labels, src, dst, elabel, *, dedup: bool = True) -> "Graph":
        """Canonicalize: sort edges by (src, elabel, dst); drop exact duplicate
        (s,l,t) triples (they are redundant under the paper's set semantics)."""
        node_labels = np.asarray(node_labels, dtype=np.int32)
        src = np.asarray(src, dtype=np.int32)
        dst = np.asarray(dst, dtype=np.int32)
        elabel = np.asarray(elabel, dtype=np.int32)
        order = np.lexsort((dst, elabel, src))
        src, dst, elabel = src[order], dst[order], elabel[order]
        if dedup and src.size:
            keep = np.ones(src.shape[0], dtype=bool)
            keep[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1]) | (
                elabel[1:] != elabel[:-1])
            src, dst, elabel = src[keep], dst[keep], elabel[keep]
        return Graph(node_labels, src, dst, elabel)

    # ----------------------------------------------------------------- CSR
    def out_offsets(self) -> np.ndarray:
        """CSR row offsets over the canonical (src-sorted) edge order: the
        analogue of E_tst."""
        counts = np.bincount(self.src, minlength=self.num_nodes)
        off = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=off[1:])
        return off

    def in_order(self) -> np.ndarray:
        """Permutation sorting edges by (dst, src): the analogue of E_tts."""
        return np.lexsort((self.src, self.dst))

    def in_offsets(self, in_order: Optional[np.ndarray] = None) -> np.ndarray:
        counts = np.bincount(self.dst, minlength=self.num_nodes)
        off = np.zeros(self.num_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=off[1:])
        return off

    # ------------------------------------------------------------------ IO
    def save(self, path: str) -> None:
        np.savez_compressed(
            path, node_labels=self.node_labels, src=self.src, dst=self.dst,
            elabel=self.elabel)

    @staticmethod
    def load(path: str) -> "Graph":
        z = np.load(path)
        return Graph(z["node_labels"], z["src"], z["dst"], z["elabel"])

    def to_ooc(self, root: str, *, chunk_nodes: int = 1 << 16,
               chunk_edges: int = 1 << 16):
        """Spill to chunked on-disk N_t/E_t tables (`repro.exmem.OocGraph`);
        inverse of `OocGraph.to_memory()`."""
        from repro.exmem.tables import OocGraph  # avoid circular import
        return OocGraph.from_graph(self, root, chunk_nodes=chunk_nodes,
                                   chunk_edges=chunk_edges)

    # --------------------------------------------------------------- edits
    def with_edges_added(self, src, dst, elabel) -> "Graph":
        return Graph.from_edges(
            self.node_labels,
            np.concatenate([self.src, np.atleast_1d(src).astype(np.int32)]),
            np.concatenate([self.dst, np.atleast_1d(dst).astype(np.int32)]),
            np.concatenate([self.elabel, np.atleast_1d(elabel).astype(np.int32)]),
        )

    def with_edges_removed(self, src, dst, elabel) -> "Graph":
        rm = set(zip(np.atleast_1d(src).tolist(), np.atleast_1d(elabel).tolist(),
                     np.atleast_1d(dst).tolist()))
        keep = np.array(
            [(s, l, t) not in rm
             for s, l, t in zip(self.src.tolist(), self.elabel.tolist(),
                                self.dst.tolist())], dtype=bool)
        return Graph(self.node_labels, self.src[keep], self.dst[keep],
                     self.elabel[keep])

    def with_nodes_added(self, labels) -> "Graph":
        labels = np.atleast_1d(labels).astype(np.int32)
        return Graph(np.concatenate([self.node_labels, labels]), self.src,
                     self.dst, self.elabel)


def paper_example_graph() -> Graph:
    """The 6-node social-network example from Figure 1 of the paper.

    Nodes 1,2 have label M(=0); nodes 3..6 label P(=1). Edge labels:
    l(ikes)=0, w(orks for)=1. Node ids are shifted to 0-based.
    """
    #            (3,l,1) (1,w,2) (2,w,2) (5,l,2) (4,l,3) (1,l,4) (2,l,6)
    src = np.array([2, 0, 1, 4, 3, 0, 1])
    dst = np.array([0, 1, 1, 1, 2, 3, 5])
    lab = np.array([0, 1, 1, 0, 0, 0, 0])
    node_labels = np.array([0, 0, 1, 1, 1, 1])
    return Graph.from_edges(node_labels, src, dst, lab)
