"""Paper Fig. 11: batch updates (ADD_EDGES) vs single updates vs rebuild.

Sweeps the number of edges updated at once and reports the crossover
against Build_Bisim, as in §5.5.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import BisimMaintainer, build_bisim
from repro.graph.storage import Graph

from .datasets import suite


def run(scale: int = 1, k: int = 10):
    rows = []
    for name, g in list(suite(scale).items())[:2]:
        rng = np.random.default_rng(1)
        for nedges in (1, 10, 100, 1000):
            idx = rng.choice(g.num_edges, size=nedges, replace=False)
            keep = np.ones(g.num_edges, bool)
            keep[idx] = False
            gg = Graph(g.node_labels, g.src[keep], g.dst[keep],
                       g.elabel[keep])
            m = BisimMaintainer(gg, k)
            t0 = time.perf_counter()
            rep = m.add_edges(g.src[idx], g.elabel[idx], g.dst[idx])
            dt = time.perf_counter() - t0
            t0 = time.perf_counter()
            build_bisim(g, k)
            dt_build = time.perf_counter() - t0
            rows.append((
                f"batch_updates/{name}/edges={nedges}", dt * 1e6,
                f"rebuild_us={dt_build * 1e6:.0f};"
                f"update_wins={dt < dt_build};rebuilt={rep.rebuilt}"))
    return rows
