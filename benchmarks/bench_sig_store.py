"""Paper Fig. 4: signature-store implementations compared.

The paper compares BerkeleyDB B-Tree vs Hash for S. Three TPU-native axes
here:

  * the three signature modes driving the bulk store during construction:
    'sorted' (paper-faithful 3-key sort), 'dedup_hash' (fused-hash
    single-key sort) and 'multiset' (sort-free segment-sum);
  * the store data structure itself — the old per-key Python dict vs the
    array-backed sorted ``SigStore`` (searchsorted lookup, merge insert) —
    measured head-to-head on bulk insert + lookup at 1e5 and 1e6 keys;
  * resident-memory bounds — the in-memory ``SigStore`` vs the
    ``SpillableSigStore`` (sorted on-disk runs past a spill threshold) at
    three thresholds, insert + lookup throughput with spill/merge counts.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.core import SigStore, SpillableSigStore, build_bisim
from repro.exmem import IOStats

from .datasets import suite


def _store_head_to_head(num_keys: int, seed: int = 0):
    """dict vs SigStore: bulk insert of num_keys, then a full re-lookup."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, np.iinfo(np.int64).max, num_keys).astype(np.uint64)
    probe = rng.permutation(keys)
    # pre-convert outside the timed regions so the dict path is not charged
    # for numpy->Python conversion
    keys_list = keys.tolist()
    probe_list = probe.tolist()
    rows = []

    t0 = time.perf_counter()
    d = {}
    nxt = 0
    for k in keys_list:
        if k not in d:
            d[k] = nxt
            nxt += 1
    dict_insert = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_d = [d[k] for k in probe_list]
    dict_lookup = time.perf_counter() - t0

    t0 = time.perf_counter()
    store = SigStore.empty()
    _, nxt_s = store.get_or_assign(keys, 0)
    arr_insert = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_s, found = store.lookup(probe)
    arr_lookup = time.perf_counter() - t0
    assert found.all() and nxt_s == nxt == len(store)
    assert out_s.sum() == sum(out_d)

    rows.append((f"store_vs_dict/{num_keys}/dict_insert", dict_insert * 1e6,
                 f"keys={num_keys};unique={nxt}"))
    rows.append((f"store_vs_dict/{num_keys}/dict_lookup", dict_lookup * 1e6,
                 f"keys={num_keys}"))
    rows.append((f"store_vs_dict/{num_keys}/array_insert", arr_insert * 1e6,
                 f"keys={num_keys};unique={nxt_s};"
                 f"speedup={dict_insert / arr_insert:.2f}x"))
    rows.append((f"store_vs_dict/{num_keys}/array_lookup", arr_lookup * 1e6,
                 f"keys={num_keys};"
                 f"speedup={dict_lookup / arr_lookup:.2f}x"))
    return rows


def _spillable_head_to_head(num_keys: int, seed: int = 0,
                            batch: int = 1 << 16):
    """In-memory SigStore vs SpillableSigStore at three spill thresholds:
    batched get_or_assign inserts then a full random re-lookup."""
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, np.iinfo(np.int64).max, num_keys).astype(np.uint64)
    probe = rng.permutation(keys)
    rows = []

    t0 = time.perf_counter()
    mem = SigStore.empty()
    nxt = 0
    for s in range(0, num_keys, batch):
        _, nxt = mem.get_or_assign(keys[s:s + batch], nxt)
    mem_insert = time.perf_counter() - t0
    t0 = time.perf_counter()
    out_mem, found = mem.lookup(probe)
    mem_lookup = time.perf_counter() - t0
    assert found.all()
    rows.append((f"spillable/{num_keys}/inmemory_insert", mem_insert * 1e6,
                 f"keys={num_keys};unique={nxt}"))
    rows.append((f"spillable/{num_keys}/inmemory_lookup", mem_lookup * 1e6,
                 f"keys={num_keys}"))

    for frac in (2, 8, 32):
        thr = max(num_keys // frac, 1)
        with tempfile.TemporaryDirectory() as td:
            io = IOStats()
            store = SpillableSigStore(spill_threshold=thr, spill_dir=td,
                                      io=io)
            t0 = time.perf_counter()
            nxt_s = 0
            for s in range(0, num_keys, batch):
                _, nxt_s = store.get_or_assign(keys[s:s + batch], nxt_s)
            sp_insert = time.perf_counter() - t0
            t0 = time.perf_counter()
            out_sp, found = store.lookup(probe)
            sp_lookup = time.perf_counter() - t0
            assert found.all() and nxt_s == nxt
            assert out_sp.sum() == out_mem.sum()
            rows.append((
                f"spillable/{num_keys}/thr{frac}_insert", sp_insert * 1e6,
                f"threshold={thr};spills={io.spills};"
                f"merges={io.merge_passes};"
                f"vs_inmemory={sp_insert / mem_insert:.2f}x"))
            rows.append((
                f"spillable/{num_keys}/thr{frac}_lookup", sp_lookup * 1e6,
                f"threshold={thr};runs={store.num_spilled_runs};"
                f"vs_inmemory={sp_lookup / mem_lookup:.2f}x"))
    return rows


def run(scale: int = 1, k: int = 10):
    rows = []
    for name, g in list(suite(scale).items())[:4]:
        for mode in ("sorted", "dedup_hash", "multiset"):
            t0 = time.perf_counter()
            res = build_bisim(g, k, mode=mode)
            dt = time.perf_counter() - t0
            total_sorted = sum(s.bytes_sorted for s in res.stats)
            rows.append((
                f"sigstore/{name}/{mode}", dt * 1e6,
                f"final_partitions={res.counts[-1]};"
                f"bytes_sorted={total_sorted};iters={len(res.counts) - 1}"))
    for num_keys in (10**5, 10**6 * scale):
        rows.extend(_store_head_to_head(num_keys))
    rows.extend(_spillable_head_to_head(10**6 * scale))
    return rows
