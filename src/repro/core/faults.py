"""Deterministic fault injection for the out-of-core engine.

Crash safety is only a property once it is *provoked*: this module is
the seam through which tests (and the CI ``crash-recovery`` job) inject
I/O failures at exact, reproducible points.  The aio primitives, the
table/meta writers, the WAL, and the device propagation path each call
`fault_point(kind, path)` before (or around) their side effect; with no
plan installed the call is a counter-free no-op.

A `FaultPlan` is a deterministic schedule over the global sequence of
fault points:

  * ``crash_at=n``      — the n-th fault point (1-based) raises
                          `InjectedCrash`, simulating the process dying
                          right there; nothing after it runs.
  * ``transient_at``    — these points raise `TransientIOError` (a flaky
                          device), each up to ``transient_repeats``
                          times; `with_retries` callers recover, others
                          propagate.
  * ``torn_at=n``       — the n-th point *returns* ``"torn"``: writers
                          that support it publish a corrupted file and
                          then raise `InjectedCrash`, simulating a
                          rename that reached the disk before the data
                          blocks did (the failure mode checksums exist
                          to catch).
  * ``kinds``           — restrict triggering to these kinds; other
                          points still count (so indices are stable
                          when narrowing a schedule).

Plans also *observe*: every firing of a fault point appends to
``plan.log``, so a harness can first run a scenario under an empty plan
to learn how many kill points it has, then re-run with ``crash_at``
sweeping that range — the "kill at any injected fault point" loop of
the crash-recovery fuzz harness.

`with_retries` is the matching graceful-degradation primitive: bounded
retry with exponential backoff for `TransientIOError` only —
`InjectedCrash` (and every real non-transient error) always propagates
on the first throw.

Thread-safety: fault points may fire from aio worker threads; the plan
guards its counter with a lock, so a schedule is deterministic whenever
the fault points themselves are issued in a deterministic order (the
crash-recovery fuzz runs with ``io_threads=0`` for exactly this
reason).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Callable, Optional

from ..obs import tracer as obs

MAX_RETRIES = 4
BACKOFF_S = 0.002


class TransientIOError(OSError):
    """A retriable I/O failure (flaky device, injected or real)."""


class InjectedCrash(RuntimeError):
    """A simulated process death at an injected fault point."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic schedule over the global fault-point sequence."""

    crash_at: Optional[int] = None       # 1-based index raising InjectedCrash
    transient_at: tuple = ()             # indices raising TransientIOError
    transient_repeats: int = 1           # throws per transient index
    torn_at: Optional[int] = None        # index returning the "torn" verdict
    kinds: Optional[frozenset] = None    # restrict triggers to these kinds

    def __post_init__(self):
        self._lock = threading.Lock()
        self._count = 0
        self._transient_left = {int(i): int(self.transient_repeats)
                                for i in self.transient_at}
        self.log: list = []              # (index, kind, path) of every point

    @property
    def points_seen(self) -> int:
        with self._lock:
            return self._count

    def fire(self, kind: str, path: Optional[str]) -> Optional[str]:
        with self._lock:
            self._count += 1
            idx = self._count
            self.log.append((idx, kind, path))
            obs.event("fault.point", kind=kind, path=path, index=idx)
            if self.kinds is not None and kind not in self.kinds:
                return None
            if self.crash_at is not None and idx == self.crash_at:
                obs.event("fault.crash", kind=kind, path=path, index=idx)
                raise InjectedCrash(
                    f"injected crash at fault point {idx} ({kind}: {path})")
            if self._transient_left.get(idx, 0) > 0:
                self._transient_left[idx] -= 1
                # transient errors re-fire on retry at *new* indices; keep
                # the budget keyed by the original index so a retried op
                # eventually succeeds
                self._transient_left[idx + 1] = self._transient_left.pop(idx)
                obs.event("fault.transient", kind=kind, path=path, index=idx)
                raise TransientIOError(
                    f"injected transient I/O error at fault point {idx} "
                    f"({kind}: {path})")
            if self.torn_at is not None and idx == self.torn_at:
                obs.event("fault.torn", kind=kind, path=path, index=idx)
                return "torn"
        return None


_ACTIVE: Optional[FaultPlan] = None


def fault_point(kind: str, path: Optional[str] = None) -> Optional[str]:
    """Hook called by I/O primitives before (or around) a side effect.
    No-op unless a plan is installed; returns ``"torn"`` when the caller
    should publish a corrupted artifact before crashing."""
    plan = _ACTIVE
    if plan is None:
        return None
    return plan.fire(kind, path)


@contextlib.contextmanager
def install_fault_plan(plan: FaultPlan):
    """Install ``plan`` as the process-wide schedule for the duration."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


def with_retries(fn: Callable, *, retries: int = MAX_RETRIES,
                 backoff_s: float = BACKOFF_S):
    """Run ``fn``, retrying `TransientIOError` with exponential backoff.

    Only transient errors are retried — `InjectedCrash` and every other
    exception propagate immediately, so a simulated process death is
    never "survived" by the retry loop.  The final attempt's error
    propagates after the budget is exhausted.
    """
    for attempt in range(retries):
        try:
            return fn()
        except TransientIOError:
            obs.event("fault.retry", attempt=attempt + 1)
            time.sleep(backoff_s * (2 ** attempt))
    return fn()
