"""Quickstart: k-bisimulation partitioning in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import BisimMaintainer, build_bisim, oracle_pids, same_partition
from repro.graph import generators as gen
from repro.graph.storage import paper_example_graph


def main():
    # 1. the paper's Figure-1 social network
    g = paper_example_graph()
    res = build_bisim(g, k=2, early_stop=False)
    print("paper example block counts per iteration:", res.counts)
    print("pId_2 per node:", res.pids[2].tolist())

    # 2. a bigger random graph, all three signature modes
    g = gen.powerlaw_graph(50_000, 200_000, num_node_labels=4, seed=0)
    for mode in ("sorted", "dedup_hash", "multiset"):
        res = build_bisim(g, k=10, mode=mode)
        print(f"mode={mode:10s} partitions={res.counts[-1]:6d} "
              f"converged_at={res.converged_at} "
              f"time={sum(s.seconds for s in res.stats):.2f}s")

    # 3. incremental maintenance (Algorithm 4) vs rebuild
    g = gen.random_graph(2_000, 6_000, 3, 2, seed=1)
    m = BisimMaintainer(g, k=5)
    rep = m.add_edge(10, 0, 20)
    print("add_edge nodes checked per level:", rep.nodes_checked)
    assert same_partition(m.pid(), build_bisim(m.graph, 5,
                                               early_stop=False).pids[5])
    print("maintenance == rebuild: OK")

    # 4. exact-oracle validation on a small graph
    g = gen.random_graph(100, 300, 3, 2, seed=2)
    res = build_bisim(g, 4, early_stop=False)
    ora = oracle_pids(g, 4, early_stop=False)
    assert all(same_partition(res.pids[j], ora[j]) for j in range(5))
    print("oracle validation: OK")


if __name__ == "__main__":
    main()
