"""Fused-vs-staged parity and dispatch-count contracts (PR 8).

The fused build (`build_bisim(fused=True)`) and the fused store resolve
(`DeviceSigStore.probe_mint_insert`) must be bit-identical to their
staged references — same pids, same per-iteration counts, same store
contents — while honouring the one-sync contract the docstrings
advertise.  These tests are the oracle those docstrings point at.
"""

import numpy as np
import pytest

import repro.core.device_maint as dm
from repro import obs
from repro.core import partition
from repro.core.device_maint import DeviceSigStore, bucket
from repro.core.sig_store import SigStore
from repro.graph import generators

jax = pytest.importorskip("jax")
jnp = jax.numpy


GRAPHS = {
    "random": lambda: generators.random_graph(120, 500, 4, 3, seed=11),
    "powerlaw": lambda: generators.powerlaw_graph(150, 700, 3, 2, seed=5),
    "dag": lambda: generators.random_dag(100, 380, 4, 2, seed=2),
}
MODES = ["multiset", "sorted", "dedup_hash"]


# --------------------------------------------------------------- build parity
@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("mode", MODES)
def test_fused_build_matches_staged(gname, mode):
    g = GRAPHS[gname]()
    fused = partition.build_bisim(g, 6, mode=mode, fused=True)
    for sync_every in (1, 3):
        staged = partition.build_bisim(g, 6, mode=mode, fused=False,
                                       sync_every=sync_every)
        np.testing.assert_array_equal(fused.pids, staged.pids)
        assert fused.counts == staged.counts
        assert fused.converged_at == staged.converged_at
        # non-timing stats must agree too (bytes metrics are derived from
        # the same shapes, seconds is wall-clock and excluded)
        for a, b in zip(fused.stats, staged.stats):
            assert (a.iteration, a.num_partitions) == \
                (b.iteration, b.num_partitions)
            assert (a.bytes_sorted, a.bytes_scanned) == \
                (b.bytes_sorted, b.bytes_scanned)


@pytest.mark.parametrize("early_stop", [True, False])
def test_fused_build_early_stop_parity(early_stop):
    g = GRAPHS["random"]()
    fused = partition.build_bisim(g, 8, mode="sorted", fused=True,
                                  early_stop=early_stop)
    staged = partition.build_bisim(g, 8, mode="sorted", fused=False,
                                   early_stop=early_stop)
    np.testing.assert_array_equal(fused.pids, staged.pids)
    assert fused.converged_at == staged.converged_at


def test_fused_build_with_store_raises():
    g = GRAPHS["random"]()
    with pytest.raises(ValueError, match="fused"):
        partition.build_bisim(g, 3, fused=True, with_store=True)


# ----------------------------------------------------------- dispatch counts
def test_fused_build_single_sync():
    """The fused-build contract: exactly ONE device->host sync (the final
    history fetch) and ONE dispatch for the entire k-loop."""
    g = GRAPHS["powerlaw"]()
    with obs.tracing() as tracer:
        partition.build_bisim(g, 6, mode="multiset", fused=True)
    syncs = tracer.find_events("build.sync")
    dispatches = tracer.find_events("build.dispatch")
    assert len(syncs) == 1
    assert len(dispatches) == 1
    assert dispatches[0]["attrs"]["path"] == "fused"


def test_staged_build_sync_count_scales_with_sync_every():
    g = GRAPHS["powerlaw"]()
    counts = {}
    for sync_every in (1, 3):
        with obs.tracing() as tracer:
            partition.build_bisim(g, 6, mode="multiset", fused=False,
                                  sync_every=sync_every)
        counts[sync_every] = len(tracer.find_events("build.sync"))
    assert counts[1] > counts[3] >= 1


# ------------------------------------------------------ store resolve parity
def _fresh_pair(entries=()):
    """A host SigStore and its device mirror holding the same entries."""
    host = SigStore.empty()
    next_pid = 0
    if len(entries):
        keys = np.asarray(entries, dtype=np.uint64)
        _, next_pid = host.get_or_assign(keys, next_pid)
    return host, DeviceSigStore(host), next_pid


def _staged_resolve(dev, qhi, qlo, count, next_pid):
    """Reference ladder: _probe_step -> _resolve_step -> _merge_step."""
    out, n_miss = dm._probe_step(dev.khi, dev.klo, dev.kpid, qhi, qlo,
                                 jnp.int32(count), jnp.int32(dev.size))
    n_miss = int(n_miss)
    if n_miss == 0:
        return np.asarray(jax.device_get(out[:count])).astype(np.int64), \
            next_pid
    out, n_novel, sh, sl, minted, is_first = dm._resolve_step(
        dev.khi, dev.klo, dev.kpid, qhi, qlo,
        jnp.int32(count), jnp.int32(dev.size), jnp.int32(next_pid))
    n = int(n_novel)
    cap = dev.khi.shape[0]
    new_cap = cap if dev.size + n <= cap else bucket(dev.size + n)
    dev.khi, dev.klo, dev.kpid = dm._merge_step(
        dev.khi, dev.klo, dev.kpid, sh, sl, minted, is_first,
        jnp.int32(dev.size), new_cap=new_cap)
    dev.size += n
    dev._host = None
    return np.asarray(jax.device_get(out[:count])).astype(np.int64), \
        next_pid + n


def _random_probes(rng, count, pool):
    keys = rng.choice(pool, size=count)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    lo = keys.astype(np.uint32)
    p = bucket(count)
    qhi = np.zeros(p, np.uint32)
    qlo = np.zeros(p, np.uint32)
    qhi[:count] = hi
    qlo[:count] = lo
    return qhi, qlo


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_probe_mint_insert_matches_staged(seed):
    rng = np.random.default_rng(seed)
    pool = rng.integers(1, 2**63, size=400, dtype=np.uint64)
    _, fused_dev, np_f = _fresh_pair(pool[:50])
    _, staged_dev, np_s = _fresh_pair(pool[:50])
    host = SigStore.empty()
    keys0 = np.asarray(pool[:50], dtype=np.uint64)
    _, np_h = host.get_or_assign(keys0, 0)
    for _ in range(6):
        count = int(rng.integers(1, 120))
        qhi, qlo = _random_probes(rng, count, pool)
        got_f, np_f = fused_dev.probe_mint_insert(qhi, qlo, count, np_f)
        got_s, np_s = _staged_resolve(staged_dev, qhi, qlo, count, np_s)
        keys = (qhi[:count].astype(np.uint64) << np.uint64(32)) \
            | qlo[:count].astype(np.uint64)
        got_h, np_h = host.get_or_assign(keys, np_h)
        np.testing.assert_array_equal(got_f, got_s)
        np.testing.assert_array_equal(got_f, got_h)
        assert np_f == np_s == np_h
    # mirrored store contents identical to the host store
    np.testing.assert_array_equal(fused_dev.to_host().keys, host.keys)
    np.testing.assert_array_equal(fused_dev.to_host().pids, host.pids)


def test_probe_mint_insert_empty_store_all_novel():
    """Edge cases: resolving against an empty store (everything minted)
    and a second all-novel batch that forces a capacity regrow."""
    _, dev, next_pid = _fresh_pair()
    assert dev.size == 0
    keys = np.arange(1, 11, dtype=np.uint64) * np.uint64(0x9E3779B9)
    hi = (keys >> np.uint64(32)).astype(np.uint32)
    lo = keys.astype(np.uint32)
    p = bucket(10)
    qhi = np.zeros(p, np.uint32)
    qlo = np.zeros(p, np.uint32)
    qhi[:10], qlo[:10] = hi, lo
    got, next_pid = dev.probe_mint_insert(qhi, qlo, 10, next_pid)
    # all novel: pids are dense 0..9 in first-occurrence order
    np.testing.assert_array_equal(np.sort(got), np.arange(10))
    assert next_pid == 10 and dev.size == 10
    # second all-novel wave exceeding capacity; probing old keys again
    # must return the original pids
    keys2 = np.arange(100, 160, dtype=np.uint64) * np.uint64(0x85EBCA6B)
    count2 = keys2.size + keys.size
    allk = np.concatenate([keys, keys2])
    p2 = bucket(count2)
    qhi2 = np.zeros(p2, np.uint32)
    qlo2 = np.zeros(p2, np.uint32)
    qhi2[:count2] = (allk >> np.uint64(32)).astype(np.uint32)
    qlo2[:count2] = allk.astype(np.uint32)
    got2, next_pid = dev.probe_mint_insert(qhi2, qlo2, count2, next_pid)
    np.testing.assert_array_equal(got2[:10], got)
    assert next_pid == 10 + keys2.size
    host = dev.to_host()
    assert len(host.keys) == dev.size == 10 + keys2.size


def test_probe_mint_insert_duplicate_probes_one_pid():
    """Duplicate novel keys inside one batch mint exactly one pid."""
    _, dev, next_pid = _fresh_pair()
    k = np.uint64(0xDEADBEEFCAFE)
    qhi = np.zeros(8, np.uint32)
    qlo = np.zeros(8, np.uint32)
    qhi[:4] = np.uint32(k >> np.uint64(32))
    qlo[:4] = np.uint32(k & np.uint64(0xFFFFFFFF))
    got, next_pid = dev.probe_mint_insert(qhi, qlo, 4, next_pid)
    assert next_pid == 1 and dev.size == 1
    np.testing.assert_array_equal(got, np.zeros(4, np.int64))


# -------------------------------------------------------------- bucket policy
def test_bucket_floor_and_waste():
    assert bucket(0) == dm.BUCKET_FLOOR
    assert bucket(1) == dm.BUCKET_FLOOR
    assert bucket(dm.BUCKET_FLOOR) == dm.BUCKET_FLOOR
    for n in [9, 17, 100, 1000, 4097, 65537]:
        b = bucket(n)
        assert b >= n and (b & (b - 1)) == 0
        if n >= dm.BUCKET_FLOOR:
            assert b < 2 * n, f"bucket({n})={b} wastes >= 2x"
    assert bucket(3, floor=1) == 4
    assert bucket(0, floor=64) == 64
    with pytest.raises(ValueError, match="power of two"):
        bucket(10, floor=3)
    with pytest.raises(ValueError, match="power of two"):
        bucket(10, floor=0)


def test_bucketing_bounds_compiled_programs():
    """Regression guard for the jit-cache: resolving a sweep of batch
    sizes against one store may only compile O(log n) distinct
    probe-program shapes — one per (capacity, probe) bucket pair."""
    _, dev, next_pid = _fresh_pair()
    rng = np.random.default_rng(3)
    shapes = set()
    for count in [1, 2, 3, 5, 7, 8, 9, 15, 17, 31, 40, 63, 70, 100, 127]:
        keys = rng.integers(1, 2**63, size=count, dtype=np.uint64)
        p = bucket(count)
        qhi = np.zeros(p, np.uint32)
        qlo = np.zeros(p, np.uint32)
        qhi[:count] = (keys >> np.uint64(32)).astype(np.uint32)
        qlo[:count] = keys.astype(np.uint32)
        _, next_pid = dev.probe_mint_insert(qhi, qlo, count, next_pid)
        shapes.add((p, dev.khi.shape[0]))
    # 15 distinct counts; buckets collapse them to a handful of shapes
    assert len(shapes) <= 8, shapes
