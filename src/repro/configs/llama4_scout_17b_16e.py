"""llama4-scout-17b-16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 routed experts top-1 + 1 shared (Llama-4 design).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    head_dim=128,
    layer_pattern=("moe",),
    num_experts=16,
    num_shared_experts=1,
    moe_top_k=1,
    rope_theta=500000.0,
)

SMOKE_CONFIG = CONFIG.scaled(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
    vocab_size=128, head_dim=16, num_experts=4, vocab_pad_multiple=8)
